"""Paper Figs. 6-9: end-to-end serving comparisons (simulator).

fig6/7  single cluster  (24 nodes: 4xA100 + 8xL4 + 12xT4), LLaMA 30B/70B
fig8/9  distributed     (3 regions, 100 Mb/s + 50 ms WAN)
fig9e   high-heterogeneity (42 nodes, 7 device types), LLaMA 70B offline
"""
from __future__ import annotations

from repro.core import (LLAMA_30B, LLAMA_70B, make_distributed_cluster,
                        make_high_heterogeneity_cluster, make_single_cluster)

from .common import emit, make_placement, run_serving


def _compare(name, cluster, model, methods, *, offline, num_requests=None,
             quick=False):
    if num_requests is None:
        # offline runs need enough concurrency to pressure KV capacity
        # (that's where §4.2 estimation pays off)
        num_requests = 700 if offline else 300
    if quick:
        num_requests = min(num_requests, 150)
    rows = {}
    for pm, sm in methods:
        r = run_serving(cluster, model, pm, sm, offline=offline,
                        num_requests=num_requests)
        rows[r.method] = r
        mode = "offline" if offline else "online"
        emit(f"{name}_{mode}_{pm}-{sm}_decode_tps", r.wall_s,
             f"{r.decode_throughput:.1f}")
        if not offline:
            emit(f"{name}_{mode}_{pm}-{sm}_prompt_lat_s", r.wall_s,
                 f"{r.prompt_latency['mean']:.3f}")
            emit(f"{name}_{mode}_{pm}-{sm}_decode_lat_s", r.wall_s,
                 f"{r.decode_latency['mean']:.3f}")
    return rows


METHODS = [("helix", "helix"), ("swarm", "swarm"), ("sp", "helix")]


def bench_single_cluster(quick: bool = False):
    """Fig. 6 + Fig. 7 (single cluster, offline + online)."""
    cluster = make_single_cluster()
    out = {}
    for model in (LLAMA_30B, LLAMA_70B):
        for offline in (True, False):
            rows = _compare(f"fig6_single_{model.name}", cluster, model,
                            METHODS, offline=offline, quick=quick)
            out[(model.name, offline)] = rows
    # paper claim: helix >= ~1.9x swarm decode throughput on 70B offline
    rows = out[("llama-70b", True)]
    ratio = rows["helix/helix"].decode_throughput / max(
        rows["swarm/swarm"].decode_throughput, 1e-9)
    emit("fig6_70b_offline_helix_vs_swarm_ratio", 0.0, f"{ratio:.2f}")
    return out


def bench_distributed_cluster(quick: bool = False):
    """Fig. 8 + Fig. 9a-d (distributed clusters)."""
    cluster = make_distributed_cluster()
    out = {}
    for model in (LLAMA_30B, LLAMA_70B):
        for offline in (True, False):
            rows = _compare(f"fig8_dist_{model.name}", cluster, model,
                            METHODS, offline=offline, quick=quick)
            out[(model.name, offline)] = rows
    rows = out[("llama-70b", True)]
    ratio = rows["helix/helix"].decode_throughput / max(
        rows["swarm/swarm"].decode_throughput, 1e-9)
    emit("fig8_70b_offline_helix_vs_swarm_ratio", 0.0, f"{ratio:.2f}")
    return out


def bench_pipelined_decode(quick: bool = False):
    """In-flight decode window: depth 1 vs 2 on a 3xA100 full mesh with
    50 ms links — LLaMA-30B forces a real 3-stage pipeline, so depth 2's
    final-stage launch turns the (k+1)·d per-token path into k·d.  Light
    online load and decode_chunk=1 so the per-request serial path (not
    queueing or chunk amortization) is what's measured."""
    import time

    from repro.core import MILPOptions, full_mesh_cluster, plan
    from repro.sim import Simulator, make_trace

    cluster = full_mesh_cluster(3, bandwidth=1e9 / 8, latency_s=50e-3)
    p = plan(cluster, LLAMA_30B, MILPOptions(time_limit_s=15.0,
                                             lns_rounds=0))
    n = 60 if quick else 150
    trace = make_trace(n, arrival_rate_per_s=1.0, seed=0)
    rows = {}
    for depth in (1, 2):
        t0 = time.time()
        sim = Simulator(cluster, LLAMA_30B, p.placement, p.make_scheduler(),
                        warmup_s=5.0, horizon_s=600.0, decode_chunk=1,
                        max_inflight=depth)
        m = sim.run(list(trace))
        rows[depth] = m
        wall = time.time() - t0
        emit(f"pipelined_llama-30b_3stage_depth{depth}_decode_lat_s",
             wall, f"{m.decode_latency['mean']:.3f}")
        emit(f"pipelined_llama-30b_3stage_depth{depth}_decode_tps",
             wall, f"{m.decode_throughput:.1f}")
    ratio = rows[1].decode_latency["mean"] / max(
        rows[2].decode_latency["mean"], 1e-9)
    emit("pipelined_llama-30b_depth1_vs_depth2_lat_ratio", 0.0,
         f"{ratio:.2f}")
    return rows


def bench_high_heterogeneity(quick: bool = False):
    """Fig. 9e (42 nodes, 7 types, LLaMA-70B offline)."""
    cluster = make_high_heterogeneity_cluster()
    methods = [("helix", "helix"), ("swarm", "swarm"), ("sp", "helix"),
               ("sp+", "helix")]
    rows = _compare("fig9e_42node_llama-70b", cluster, LLAMA_70B, methods,
                    offline=True, quick=quick)
    helix = rows["helix/helix"].decode_throughput
    for key, label in [("swarm/swarm", "swarm"), ("sp/helix", "sp"),
                       ("sp+/helix", "sp_plus")]:
        ratio = helix / max(rows[key].decode_throughput, 1e-9)
        emit(f"fig9e_helix_vs_{label}_ratio", 0.0, f"{ratio:.2f}")
    return rows


def bench_kv_quant(quick: bool = False):
    """Int8 KV pages: pool capacity at fixed VRAM, and the variable-context
    decode kernel's HBM page traffic on a ragged batch.

    Two claims are pinned: (a) quantized pages give >= 1.8x the token
    capacity of param-dtype pages from the same VRAM (1-byte elements, the
    absmax scales cost only 4/page_size bytes per token amortized); (b) the
    scalar-prefetched variable-context kernel streams only the *live* pages
    of each sequence per step — strictly fewer than the dense-grid
    B x blocks_per_seq schedule whenever any sequence is shorter than the
    full budget."""
    import time

    import numpy as np

    from repro.configs import get_smoke_config
    from repro.kernels.paged_attention import streamed_pages_per_step
    from repro.serving import pages_for_vram

    cfg = get_smoke_config("smollm_360m")
    page = 16
    t0 = time.time()
    # big enough that the params leave meaningful pool headroom
    vram = 4e9
    base = pages_for_vram(cfg, vram, page_size=page)
    quant = pages_for_vram(cfg, vram, page_size=page, kv_dtype="int8")
    ratio = quant / max(base, 1)
    wall = time.time() - t0
    emit("kv_quant_pool_pages_param", wall, f"{base}")
    emit("kv_quant_pool_pages_int8", wall, f"{quant}")
    emit("kv_quant_capacity_ratio", 0.0, f"{ratio:.2f}")
    assert ratio >= 1.8, \
        f"int8 pool capacity ratio {ratio:.2f} < 1.8x"

    # ragged batch: the paper's serving mix — a few long contexts among
    # many short ones.  max_len 2048 -> 128 blocks_per_seq at page 16.
    max_len = 2048
    blocks_per_seq = -(-max_len // page)
    lengths = np.array([17, 64, 200, 1024, 33, 2048, 5, 400], np.int32)
    dense_pages = len(lengths) * blocks_per_seq
    live_pages = streamed_pages_per_step(lengths, page)
    emit("kv_quant_ragged_dense_pages_per_step", 0.0, f"{dense_pages}")
    emit("kv_quant_ragged_streamed_pages_per_step", 0.0, f"{live_pages}")
    emit("kv_quant_ragged_traffic_ratio", 0.0,
         f"{dense_pages / max(live_pages, 1):.2f}")
    assert live_pages < dense_pages, \
        "variable-context kernel must stream fewer pages than the dense grid"
    return {"capacity_ratio": ratio, "streamed": live_pages,
            "dense": dense_pages}


def bench_direct_links(quick: bool = False):
    """Routed worker-to-worker forwarding vs coordinator-star routing on a
    delayed 3-stage mesh, measured on the REAL runtime (in-process
    transport, virtual clock) with per-(src,dst) hop counters.

    Star mode bounces every inter-stage frame through the coordinator, so
    a k-stage pipeline pays 2k transport hops per decode token; direct
    links pay k+1 (k-1 peer hops, plus the token's launch + return hops
    which always touch the coordinator).  With per-link delay d the
    per-token decode latency drops from 2k*d to (k+1)*d."""
    import dataclasses
    import time

    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import (LayerRange, ModelProfile, Placement,
                            full_mesh_cluster, plan)
    from repro.models import init
    from repro.serving import (ClusterRuntime, EngineConfig,
                               InProcessTransport, Request)

    cfg = dataclasses.replace(get_smoke_config("smollm_360m"),
                              param_dtype="float32",
                              compute_dtype="float32")
    profile = ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)
    k = 3
    placement = Placement({"n0": LayerRange(0, 2), "n1": LayerRange(2, 3),
                           "n2": LayerRange(3, 4)}, cfg.num_layers)
    cluster = full_mesh_cluster(k, latency_s=2e-3)
    p = plan(cluster, profile, placement=placement)
    params = init(cfg, jax.random.key(0))
    ec = EngineConfig(max_batch=4, max_len=48, prompt_len=16)
    rng = np.random.RandomState(0)
    n_req, new_tokens = (2, 4) if quick else (4, 6)
    d = 2e-3
    rows = {}
    for mode, direct in (("star", False), ("direct", True)):
        t0 = time.time()
        tr = InProcessTransport(default_delay_s=d, direct_links=direct)
        rt = ClusterRuntime(cfg, params, p, ec, paged=True, transport=tr)
        reqs = [Request(i, rng.randint(0, cfg.vocab_size, size=(10,)),
                        max_new_tokens=new_tokens) for i in range(n_req)]
        for r in reqs:
            rt.submit(r)
        rt.run_until_done()
        wall = time.time() - t0
        n_tokens = sum(len(r.output) for r in reqs)
        hops = sum(tr.transfers.values()) / max(n_tokens, 1)
        lat = rt.mean_decode_latency()
        rows[mode] = {"hops_per_token": hops, "decode_lat_s": lat}
        emit(f"direct_links_3stage_{mode}_hops_per_token", wall,
             f"{hops:.2f}")
        emit(f"direct_links_3stage_{mode}_decode_lat_s", 0.0, f"{lat:.4f}")
    emit("direct_links_3stage_hop_ratio", 0.0,
         f"{rows['star']['hops_per_token'] / rows['direct']['hops_per_token']:.2f}")
    assert rows["star"]["hops_per_token"] == 2 * k, rows
    assert rows["direct"]["hops_per_token"] == k + 1, rows
    assert rows["direct"]["decode_lat_s"] < rows["star"]["decode_lat_s"]
    return rows


def bench_spec_decode(quick: bool = False):
    """Draft-model speculative decoding on the REAL runtime over a delayed
    3-stage mesh: one verify pass confirms up to gamma+1 tokens per
    pipeline round-trip, multiplying tokens-per-round-trip where the
    in-flight window (depth >= 2) can only hide the return hop.

    Pinned: (a) speculative greedy output is BYTE-IDENTICAL to the
    non-speculative reference for dense, paged param-dtype, and paged int8
    KV; (b) a high-acceptance draft sustains >= 2 tokens per round-trip;
    (c) per-token decode latency beats the max_inflight-only baseline on
    the same delayed mesh."""
    import dataclasses
    import time

    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import (LayerRange, ModelProfile, Placement,
                            full_mesh_cluster, plan)
    from repro.models import init
    from repro.serving import (ClusterRuntime, EngineConfig,
                               InProcessTransport, Request)

    cfg = dataclasses.replace(get_smoke_config("smollm_360m"),
                              param_dtype="float32",
                              compute_dtype="float32")
    profile = ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)
    placement = Placement({"n0": LayerRange(0, 2), "n1": LayerRange(2, 3),
                           "n2": LayerRange(3, 4)}, cfg.num_layers)
    cluster = full_mesh_cluster(3, latency_s=2e-3)
    p = plan(cluster, profile, placement=placement)
    params = init(cfg, jax.random.key(0))
    # the draft IS the target architecture re-initialised at the same key:
    # near-perfect acceptance, the high-acceptance end of the spectrum
    draft_kw = dict(draft_cfg=cfg, draft_params=init(cfg, jax.random.key(0)),
                    spec_tokens=4)
    ec = EngineConfig(max_batch=4, max_len=48, prompt_len=16)
    n_req, new_tokens = (2, 4) if quick else (4, 8)
    prompt_rng = np.random.RandomState(0)
    prompts = [prompt_rng.randint(0, cfg.vocab_size, size=(10,))
               for _ in range(n_req)]

    def serve(*, paged, kv_dtype=None, depth=1, spec=False):
        tr = InProcessTransport(default_delay_s=2e-3)
        rt = ClusterRuntime(cfg, params, p, ec, paged=paged,
                            kv_dtype=kv_dtype, transport=tr,
                            max_inflight=depth,
                            **(draft_kw if spec else {}))
        reqs = [Request(i, pr, max_new_tokens=new_tokens)
                for i, pr in enumerate(prompts)]
        for r in reqs:
            rt.submit(r)
        rt.run_until_done()
        return rt, [r.output for r in reqs]

    # (a) byte-identical greedy output across every KV layout
    for label, paged, kv in (("dense", False, None), ("paged", True, None),
                             ("int8", True, "int8")):
        t0 = time.time()
        _, ref = serve(paged=paged, kv_dtype=kv)
        rt, got = serve(paged=paged, kv_dtype=kv, spec=True)
        assert got == ref, f"spec diverged on {label}: {got} vs {ref}"
        assert rt.spec_rounds > 0
        emit(f"spec_decode_{label}_identical", time.time() - t0, "yes")

    # (b) + (c): tokens/round-trip and per-token latency vs the
    # max_inflight-only pipeline on the same delayed mesh
    t0 = time.time()
    rt_base, _ = serve(paged=True, depth=2)
    base_lat = rt_base.mean_decode_latency()
    rt_spec, _ = serve(paged=True, depth=2, spec=True)
    spec_lat = rt_spec.mean_decode_latency()
    wall = time.time() - t0
    tpr = rt_spec.spec_tokens_per_round_trip
    emit("spec_decode_3stage_tokens_per_round_trip", wall, f"{tpr:.2f}")
    emit("spec_decode_3stage_depth2_decode_lat_s", 0.0, f"{base_lat:.4f}")
    emit("spec_decode_3stage_spec_decode_lat_s", 0.0, f"{spec_lat:.4f}")
    emit("spec_decode_3stage_lat_ratio", 0.0,
         f"{base_lat / max(spec_lat, 1e-9):.2f}")
    emit("spec_decode_acceptance_rate", 0.0,
         f"{rt_spec.spec_acceptance_rate:.2f}")
    assert tpr >= 2.0, \
        f"high-acceptance draft should confirm >= 2 tokens/round-trip, " \
        f"got {tpr:.2f}"
    assert spec_lat < base_lat, \
        f"spec per-token latency {spec_lat:.4f}s should beat " \
        f"max_inflight-only {base_lat:.4f}s"
    return {"tokens_per_round_trip": tpr, "base_lat_s": base_lat,
            "spec_lat_s": spec_lat}


def bench_online_latency(quick: bool = False):
    """Latency under load through the online front door: wall-clock Poisson
    arrivals (the simulator's own arrival process) hitting the
    OpenAI-compatible HTTP API over a REAL 2-stage ClusterRuntime
    (in-process transport forced onto the wall clock), streaming SSE.

    Reported from the server-side stats (runtime monotonic clock):
    TTFT/TPOT/E2E p50/p95/p99 and SLO attainment — the latency-under-load
    axis the offline benches cannot measure.  Pinned: every request
    completes, every latency is non-negative (the clock-unification fix),
    and TTFT percentiles are finite."""
    import dataclasses
    import json
    import math
    import threading
    import time
    import urllib.request

    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import (LayerRange, ModelProfile, Placement,
                            full_mesh_cluster, plan)
    from repro.models import init
    from repro.serving import ClusterRuntime, EngineConfig, Frontend
    from repro.sim.traces import arrival_times

    cfg = dataclasses.replace(get_smoke_config("smollm_360m"),
                              param_dtype="float32",
                              compute_dtype="float32")
    profile = ModelProfile.from_dims(
        cfg.name, cfg.num_layers, cfg.d_model, max(cfg.d_ff, 1),
        cfg.vocab_size, cfg.num_kv_heads, cfg.resolved_head_dim)
    placement = Placement({"n0": LayerRange(0, 2), "n1": LayerRange(2, 4)},
                          cfg.num_layers)
    cluster = full_mesh_cluster(2, latency_s=1e-3)
    p = plan(cluster, profile, placement=placement)
    params = init(cfg, jax.random.key(0))
    ec = EngineConfig(max_batch=4, max_len=48, prompt_len=16)
    rt = ClusterRuntime(cfg, params, p, ec, paged=True, max_inflight=2,
                        realtime=True)
    fe = Frontend(rt, max_pending=32, slo_ttft_s=5.0, slo_tpot_s=2.0)
    host, port = fe.serve("127.0.0.1", 0)
    url = f"http://{host}:{port}/v1/completions"

    n, rate = (6, 4.0) if quick else (12, 6.0)
    new_tokens = 4 if quick else 6
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=(10,)).tolist()
               for _ in range(n)]
    errors = []

    def fire(i):
        body = json.dumps({"prompt": prompts[i], "max_tokens": new_tokens,
                           "stream": True}).encode()
        req = urllib.request.Request(
            url, data=body, headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=120) as resp:
                for _ in resp:
                    pass                 # stream to completion
        except Exception as e:           # collected, asserted below
            errors.append((i, repr(e)))

    t0 = time.time()
    sched = arrival_times(n, rate, seed=0)
    start = time.monotonic()
    threads = []
    for i in range(n):
        gap = start + sched[i] - time.monotonic()
        if gap > 0:
            time.sleep(gap)
        th = threading.Thread(target=fire, args=(i,), daemon=True)
        th.start()
        threads.append(th)
    for th in threads:
        th.join(timeout=180)
    fe.shutdown(drain=True)
    rt.shutdown()
    wall = time.time() - t0

    assert not errors, f"front-door requests failed: {errors}"
    s = fe.summary()
    assert s["requests"] == n, s
    for key in ("ttft_s", "tpot_s", "e2e_s"):
        for q, v in s[key].items():
            assert not (v < 0), f"negative {key} {q}: {v}"
    assert all(math.isfinite(v) for v in s["ttft_s"].values()), s
    emit("online_latency_requests", wall, f"{s['requests']}")
    emit("online_latency_offered_rate_per_s", 0.0, f"{rate:.1f}")
    for key in ("ttft_s", "tpot_s", "e2e_s"):
        for q in ("p50", "p95", "p99"):
            emit(f"online_latency_{key}_{q}", 0.0, f"{s[key][q]:.4f}")
    emit("online_latency_slo_attainment", 0.0,
         f"{s['slo_attainment']:.2f}")
    return s
