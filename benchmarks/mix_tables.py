"""Beyond-paper: cost/SLO-aware GPU-mix planning (Mélange-style).

``gpu_mix`` solves the cheapest node mix for a two-bucket traffic profile
(interactive short-context + long-prompt) under a TTFT/TPOT SLO, asserts
it meets the target rate at STRICTLY lower $/hr than the best homogeneous
cluster, then feeds the mix into the Helix MILP placement and replays the
same traffic through the event simulator — "choose the cluster" composing
with "place the model on it".
"""
from __future__ import annotations

import random
from typing import List

from repro.core import LLAMA_70B, MILPOptions, plan
from repro.core.mix_planner import (SLO, Bucket, TrafficProfile,
                                    best_homogeneous, solve_mix)
from repro.sim import Simulator
from repro.sim.traces import TraceRequest

from .common import emit


def trace_from_traffic(traffic: TrafficProfile, num_requests: int,
                       seed: int = 0) -> List[TraceRequest]:
    """Poisson arrivals at the profile's rate, lengths drawn from its
    buckets by weight — the trace the mix was solved for."""
    rng = random.Random(seed)
    out: List[TraceRequest] = []
    t = 0.0
    for i in range(num_requests):
        t += rng.expovariate(traffic.rate_rps)
        b = rng.choices(traffic.buckets, weights=traffic.weights)[0]
        out.append(TraceRequest(i, t, b.input_len, b.output_len))
    return out


def bench_gpu_mix(quick: bool = False):
    # Mélange's motivating shape: mostly short interactive traffic plus a
    # long-prompt tail whose TTFT SLO only the big GPUs can meet — so the
    # cheap types absorb the short bucket and the expensive type is bought
    # only for the tail, beating any single-type cluster on $/hr
    rate = 8.0 if quick else 20.0
    traffic = TrafficProfile(rate_rps=rate,
                             buckets=[Bucket(64, 64), Bucket(1800, 128)],
                             weights=[0.9, 0.1])
    slo = SLO(ttft_s=2.0, tpot_s=0.05)
    devices = ("A100", "V100", "L4", "T4")

    mix = solve_mix(LLAMA_70B, traffic, devices, slo=slo)
    homo = best_homogeneous(LLAMA_70B, traffic, devices, slo=slo)
    assert homo is not None, "no homogeneous cluster can serve this traffic"
    assert mix.predicted_rate_rps >= traffic.rate_rps, (
        f"solved mix serves only {mix.predicted_rate_rps:.2f} rps "
        f"< target {traffic.rate_rps}")
    assert mix.cost_per_hour < homo.cost_per_hour, (
        f"mix ${mix.cost_per_hour:.2f}/hr is not strictly cheaper than "
        f"homogeneous ${homo.cost_per_hour:.2f}/hr")

    emit("gpu_mix_solved", 0.0, mix.describe().replace(",", ";"))
    emit("gpu_mix_homogeneous", 0.0, homo.describe().replace(",", ";"))
    emit("gpu_mix_cost_per_hour", 0.0, f"{mix.cost_per_hour:.2f}")
    emit("gpu_mix_homo_cost_per_hour", 0.0, f"{homo.cost_per_hour:.2f}")
    emit("gpu_mix_savings_pct", 0.0,
         f"{100 * (1 - mix.cost_per_hour / homo.cost_per_hour):.1f}")

    # the mix is an ordinary ClusterSpec: place the model on it with the
    # existing MILP and replay the solved-for traffic through the simulator
    cluster = mix.cluster()
    p = plan(cluster, LLAMA_70B,
             MILPOptions(time_limit_s=10.0, lns_rounds=0, fgls_rounds=30))
    demand_tps = traffic.tokens_per_s()
    emit("gpu_mix_planned_tput_tps", 0.0, f"{p.throughput:.0f}")
    emit("gpu_mix_demand_tps", 0.0, f"{demand_tps:.0f}")

    n_req = 80 if quick else 200
    sim = Simulator(cluster, LLAMA_70B, p.placement, p.make_scheduler(),
                    warmup_s=5.0, horizon_s=180.0, decode_chunk=4)
    m = sim.run(trace_from_traffic(traffic, n_req, seed=7))
    emit("gpu_mix_sim_tput_tps", 0.0, f"{m.processed_throughput:.0f}")
    emit("gpu_mix_sim_completed", 0.0,
         f"{m.completed_requests}/{n_req}")
    emit("gpu_mix_sim_cost_per_mtok", 0.0,
         f"{m.dollars_per_million_tokens:.2f}")
    assert m.dropped_requests == 0, (
        f"simulated mix dropped {m.dropped_requests} requests")
    return mix, homo, m
