"""Paper Fig. 10: model placement deep dive.

Isolates placement from scheduling: Helix vs Petals vs Swarm placements all
served with Helix's scheduler (as in §5.6), LLaMA-70B offline, single and
distributed clusters.
"""
from __future__ import annotations

from repro.core import (LLAMA_70B, make_distributed_cluster,
                        make_single_cluster, placement_throughput)

from .common import emit, make_placement, run_serving


def bench_placement_deepdive(quick: bool = False):
    out = {}
    n_req = 150 if quick else 300
    for cname, cluster in [("single", make_single_cluster()),
                           ("dist", make_distributed_cluster())]:
        rows = {}
        for pm in ("helix", "petals", "swarm"):
            placement = make_placement(pm, cluster, LLAMA_70B)
            bound = placement_throughput(cluster, LLAMA_70B, placement)
            r = run_serving(cluster, LLAMA_70B, pm, "helix", offline=True,
                            num_requests=n_req, placement=placement)
            rows[pm] = r
            emit(f"fig10_{cname}_{pm}_decode_tps", r.wall_s,
                 f"{r.decode_throughput:.1f}")
            emit(f"fig10_{cname}_{pm}_flow_bound_tps", 0.0, f"{bound:.1f}")
        for other in ("petals", "swarm"):
            ratio = rows["helix"].decode_throughput / max(
                rows[other].decode_throughput, 1e-9)
            emit(f"fig10_{cname}_helix_vs_{other}_ratio", 0.0, f"{ratio:.2f}")
        out[cname] = rows
    return out
